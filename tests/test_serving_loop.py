"""Serving test tier (DESIGN.md §15): request conservation, drain/kill
semantics, LatencySLO solver parity + engine cache-key soundness, and
the zero-serving bit-identity regression.

Pins down, per ISSUE 9:

* **request conservation** — at every event, arrivals ingested ==
  served + dropped (queue/kill/timeout) + queued + in-flight, and no
  request is ever served on node-seconds the loop did not grant;
* **drain on shrink** — a graceful shrink loses zero in-flight
  requests; a hard kill loses at most one batch;
* **LatencySLO** — greedy <= fast MILP == node MILP, value_table ==
  job_value, upper bound admissible, cache keyed by exactly the fields
  the policy reads (``rate`` yes, ``slo`` no);
* **zero-serving parity** — with the serving subsystem importable but
  no serving jobs, ControlLoop replays are bit-identical to the
  AnalyticBackend across all six scenarios and all five training
  policies.
"""
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    AllocationEngine,
    AllocationProblem,
    AnalyticBackend,
    ControlLoop,
    LatencySLO,
    OBJECTIVES,
    ServingBackend,
    Throughput,
    TrainerSpec,
    amdahl_curve,
    resolve_objective,
    solve_fast_milp,
    solve_greedy,
    solve_node_milp,
)
from repro.core.engine import problem_signature
from repro.core.events import fragments_to_events
from repro.core.loop import TrainerJob
from repro.core.scaling import tab2_curve
from repro.sched.scenarios import SCENARIOS, SERVING_SCENARIOS, build_scenario
from repro.serving import (
    REQUEST_PROFILES,
    ReplicaSet,
    RequestSpec,
    RequestTrace,
    dedicated_baseline,
    run_serving,
    synthesize_requests,
)

from tests.test_engine import check_allocation_invariants


# ---------------------------------------------------------------------------
# Request traces
# ---------------------------------------------------------------------------


def test_request_traces_deterministic_and_sorted():
    for profile in REQUEST_PROFILES:
        a = synthesize_requests(profile, 6 * 3600.0, 0.5, seed=3)
        b = synthesize_requests(profile, 6 * 3600.0, 0.5, seed=3)
        c = synthesize_requests(profile, 6 * 3600.0, 0.5, seed=4)
        assert np.array_equal(a, b)
        assert len(a) and (np.diff(a) >= 0).all()
        assert 0.0 <= a[0] and a[-1] < 6 * 3600.0
        assert not (len(a) == len(c) and np.array_equal(a, c))


def test_trace_rate_in_counts_arrivals():
    tr = RequestTrace(name="t", arrivals=np.array([0.5, 1.0, 1.5, 9.0]),
                      duration=10.0, base_rate=1.0)
    assert tr.rate_in(0.0, 2.0) == pytest.approx(1.5)   # 3 requests / 2 s
    assert tr.rate_in(2.0, 9.0) == 0.0                  # 9.0 excluded
    assert tr.rate_in(5.0, 5.0) == 0.0


# ---------------------------------------------------------------------------
# Replica model: conservation property (hypothesis)
# ---------------------------------------------------------------------------

arrival_lists = st.lists(st.floats(0.0, 500.0), min_size=0, max_size=80)
segment_lists = st.lists(
    st.tuples(st.floats(1.0, 120.0),                  # dt
              st.sampled_from([0.0, 0.5, 2.0, 8.0]),  # capacity (req/s)
              st.integers(0, 4),                      # granted nodes
              st.booleans(),                          # hard-kill first?
              st.sampled_from([0.0, 0.5])),           # busy_until frac of dt
    min_size=1, max_size=12)


@given(arrival_lists, segment_lists, st.integers(1, 8), st.integers(1, 16),
       st.sampled_from([None, 3.0, 30.0]))
@settings(max_examples=40, deadline=None)
def test_replica_conservation_property(raw, segs, max_batch, max_queue,
                                       timeout):
    """At every event boundary: arrivals ingested == served + dropped
    (queue overflow, kill, timeout) + queued + in-flight; and every
    batch starts on granted nodes, never before the rescale stall."""
    arr = np.sort(np.asarray(raw, dtype=float))
    trace = RequestTrace(name="prop", arrivals=arr, duration=600.0,
                         base_rate=1.0)
    rep = ReplicaSet(trace, slo=2.0, max_batch=max_batch,
                     max_queue=max_queue, queue_timeout=timeout, audit=True)
    t = 0.0
    for dt, rate, n_nodes, kill, busy_frac in segs:
        if kill:
            lost = rep.drop_inflight(t)
            assert 0 <= lost <= max_batch
            assert rep.conserved()
        busy = t + busy_frac * dt
        n_audit = len(rep.audit)
        rep.run(t, t + dt, rate=rate, n_nodes=n_nodes, busy_until=busy)
        t += dt
        assert rep.conserved()
        for t0, k, n in rep.audit[n_audit:]:
            assert n == n_nodes and n > 0       # no stolen node-seconds
            assert 1 <= k <= max_batch
            assert t0 >= busy - 1e-12           # no start inside the stall
    end = max(t, 601.0)
    rep.run(end, end, rate=0.0, n_nodes=0)      # ingest the tail
    assert rep.idx == len(arr)
    assert rep.conserved()
    assert rep.served == rep.latency.count
    assert 0.0 <= rep.slo_attainment() <= 1.0


def test_drain_on_shrink_loses_nothing():
    """A batch started before a shrink completes at its original rate
    even after the allocation drops to zero nodes (graceful drain)."""
    arr = np.array([0.0, 0.1, 0.2, 0.3])
    rep = ReplicaSet(RequestTrace("d", arr, 100.0, 1.0), slo=100.0,
                     max_batch=4)
    rep.run(0.0, 1.0, rate=0.0, n_nodes=0)       # outage: all 4 queue up
    rep.run(1.0, 2.0, rate=0.5, n_nodes=2)       # batch of 4, done at t=9
    assert rep.inflight_size == 4 and rep.served == 0
    rep.run(2.0, 50.0, rate=0.0, n_nodes=0)      # shrunk to nothing: drain
    assert rep.served == 4
    assert rep.dropped_kill == rep.dropped_queue == rep.dropped_timeout == 0
    assert rep.conserved()


def test_kill_loses_at_most_one_batch():
    arr = np.arange(0.0, 2.0, 0.1)               # 20 requests
    rep = ReplicaSet(RequestTrace("k", arr, 100.0, 10.0), slo=100.0,
                     max_batch=4)
    rep.run(0.0, 2.0, rate=0.0, n_nodes=0)       # outage: all 20 queue up
    rep.run(2.0, 3.0, rate=0.001, n_nodes=1)     # slow: one batch in flight
    queued = len(rep.queue)
    assert rep.inflight_size == 4 and queued == 16
    lost = rep.drop_inflight(3.0)
    assert lost == 4 and rep.dropped_kill == 4
    assert len(rep.queue) == queued              # the queue survives a kill
    assert rep.conserved()


def test_queue_overflow_is_admission_controlled():
    arr = np.arange(0.0, 1.0, 0.01)              # 100 requests, no capacity
    rep = ReplicaSet(RequestTrace("q", arr, 10.0, 100.0), max_queue=8)
    rep.run(0.0, 2.0, rate=0.0, n_nodes=0)
    assert rep.idx == 100
    assert len(rep.queue) == 8 and rep.dropped_queue == 92
    assert rep.conserved()


def test_queue_timeout_abandons_stale_requests():
    """Client patience: requests queued through an outage are abandoned
    at batch formation once older than ``queue_timeout``."""
    arr = np.array([0.0, 0.1, 50.0])
    rep = ReplicaSet(RequestTrace("t", arr, 100.0, 1.0), slo=5.0,
                     max_batch=2, queue_timeout=5.0)
    rep.run(0.0, 40.0, rate=0.0, n_nodes=0)      # outage: all queued
    assert len(rep.queue) == 2
    rep.run(40.0, 80.0, rate=2.0, n_nodes=1)     # capacity returns at t=40
    assert rep.dropped_timeout == 2              # the t~0 pair is stale
    assert rep.served == 1                       # the t=50 arrival is fresh
    assert rep.conserved()


# ---------------------------------------------------------------------------
# LatencySLO: registry, tables, solver parity, admissible bound
# ---------------------------------------------------------------------------


def _serve_spec(i, thr1=2.0, n_min=1, n_max=8, comm=0.1, **extra):
    curve = amdahl_curve(f"s{i}", thr1, comm, max_nodes=n_max)
    pts, vals = curve.breakpoints(n_min, n_max)
    return TrainerSpec(id=i, n_min=n_min, n_max=n_max,
                       r_up=extra.pop("r_up", 20.0),
                       r_dw=extra.pop("r_dw", 5.0),
                       points=tuple(pts), values=tuple(vals), **extra)


def _slo_instance(seed, mixed=False):
    rng = np.random.RandomState(seed)
    n_nodes = int(rng.randint(4, 14))
    trainers, current, used = [], {}, set()
    for j in range(int(rng.randint(2, 5))):
        thr1 = float(rng.uniform(0.5, 5.0))
        n_max = int(rng.randint(2, 8))
        rate = None if (mixed and j % 2) else float(rng.uniform(0.0, 3 * thr1))
        trainers.append(_serve_spec(
            j, thr1=thr1, n_max=n_max, comm=float(rng.uniform(0.02, 0.3)),
            r_up=float(rng.uniform(0.0, 30.0)),
            r_dw=float(rng.uniform(0.0, 10.0)),
            work=1e8, rate=rate, slo=float(rng.uniform(0.2, 5.0))))
        avail = [x for x in range(n_nodes) if x not in used]
        k = int(rng.randint(0, min(n_max, len(avail)) + 1))
        current[j] = avail[:k]
        used.update(avail[:k])
    return AllocationProblem(nodes=list(range(n_nodes)), trainers=trainers,
                             current=current,
                             t_fwd=float(rng.choice([60.0, 120.0])),
                             objective=LatencySLO())


def _policy_objective(prob, counts):
    obj = resolve_objective(prob.objective)
    node_set = set(prob.nodes)
    vals = []
    for t in prob.trainers:
        cj = len([n for n in prob.current.get(t.id, []) if n in node_set])
        vals.append(obj.job_value(t, counts[t.id], cj, prob.t_fwd))
    return obj.combine(vals, prob.trainers)


def test_latency_slo_registered_and_keyed():
    assert OBJECTIVES["latency_slo"] is LatencySLO
    assert isinstance(resolve_objective("latency_slo"), LatencySLO)
    assert LatencySLO().cache_key() != LatencySLO(miss_weight=1.0).cache_key()
    assert LatencySLO().cache_key() != LatencySLO(headroom=2.0).cache_key()


def test_latency_slo_value_table_matches_job_value():
    obj = LatencySLO()
    for seed in range(6):
        prob = _slo_instance(seed, mixed=bool(seed % 2))
        for t in prob.trainers:
            cj = len(prob.current.get(t.id, []))
            tab = obj.value_table(t, cj, prob.t_fwd)
            for n in range(t.n_max + 1):
                assert tab[n] == pytest.approx(
                    obj.job_value(t, n, cj, prob.t_fwd), abs=1e-9)


def test_latency_slo_solver_parity_and_bound():
    """Fast MILP == node MILP == recomputed scalar score; greedy never
    beats the MILP; the separable upper bound is admissible."""
    for seed in range(8):
        prob = _slo_instance(seed, mixed=bool(seed % 2))
        obj = resolve_objective(prob.objective)
        rf = solve_fast_milp(prob, time_limit=60)
        rn = solve_node_milp(prob, time_limit=60)
        rg = solve_greedy(prob)
        check_allocation_invariants(prob, rf)
        check_allocation_invariants(prob, rg)
        scale = max(1.0, abs(rf.objective))
        assert rf.objective == pytest.approx(
            _policy_objective(prob, rf.counts), abs=1e-5 * scale)
        assert rn.objective == pytest.approx(rf.objective, abs=1e-5 * scale)
        assert _policy_objective(prob, rg.counts) <= rf.objective \
            + 1e-6 * scale
        cjs = [len(prob.current.get(t.id, [])) for t in prob.trainers]
        ub = obj.upper_bound(prob.trainers, cjs, len(prob.nodes), prob.t_fwd)
        assert ub >= rf.objective - 1e-6 * scale


def test_latency_slo_reduces_to_throughput_on_training_pool():
    """Jobs with ``rate=None`` are scored by plain Eqn 16, so a pure
    training pool gets the same objective value as Throughput."""
    for seed in range(4):
        rng = np.random.RandomState(seed)
        trainers = [_serve_spec(j, thr1=float(rng.uniform(1, 5)),
                                n_max=6, work=1e8)
                    for j in range(3)]
        base = AllocationProblem(nodes=list(range(8)), trainers=trainers,
                                 current={t.id: [] for t in trainers},
                                 t_fwd=120.0)
        slo = AllocationProblem(nodes=list(range(8)), trainers=trainers,
                                current={t.id: [] for t in trainers},
                                t_fwd=120.0, objective=LatencySLO())
        rb = solve_fast_milp(base, time_limit=60)
        rs = solve_fast_milp(slo, time_limit=60)
        assert rs.objective == pytest.approx(rb.objective, rel=1e-6)


def test_latency_slo_engine_cache_key_soundness():
    """The signature must move with ``rate`` (the policy reads it) and
    stay put under ``slo`` drift (it does not); training policies must
    not see ``rate`` at all."""
    def prob_with(objective, rate, slo):
        t = _serve_spec(0, work=1e8, rate=rate, slo=slo)
        return AllocationProblem(nodes=list(range(6)), trainers=[t],
                                 current={0: []}, t_fwd=120.0,
                                 objective=objective)

    pa = prob_with(LatencySLO(), 1.0, 2.0)
    pb = prob_with(LatencySLO(), 1.0, 9.0)       # slo drift: not read
    pc = prob_with(LatencySLO(), 2.5, 2.0)       # rate drift: read
    assert problem_signature(pa)[0] == problem_signature(pb)[0]
    assert problem_signature(pa)[0] != problem_signature(pc)[0]
    ta = prob_with(Throughput(), 1.0, 2.0)
    tc = prob_with(Throughput(), 2.5, 2.0)       # Throughput ignores rate
    assert problem_signature(ta)[0] == problem_signature(tc)[0]

    eng = AllocationEngine(time_budget=0.0)
    eng.allocate(pa)
    eng.allocate(pb)                             # slo drift -> cache hit
    assert eng.stats.cache_hits == 1
    eng.allocate(pc)                             # rate drift -> miss
    assert eng.stats.cache_hits == 1
    eng.allocate(prob_with(LatencySLO(miss_weight=1.0), 2.5, 2.0))
    assert eng.stats.cache_hits == 1             # params differ -> miss


# ---------------------------------------------------------------------------
# Serving scenarios + the ControlLoop end to end
# ---------------------------------------------------------------------------


class _CheckingBackend(ServingBackend):
    """ServingBackend that asserts the conservation invariant and the
    no-stolen-node-seconds audit at every loop interaction."""

    def advance(self, job, start, end):
        rep = getattr(job, "replica", None)
        n_audit = len(rep.audit) if rep is not None else 0
        out = super().advance(job, start, end)
        if rep is not None:
            assert rep.conserved()
            for t0, k, n in rep.audit[n_audit:]:
                assert n == len(job.nodes) and n > 0
                assert t0 >= job.busy_until - 1e-9
                assert start - 1e-9 <= t0 < end
        return out

    def on_fail(self, job, node, now):
        out = super().on_fail(job, node, now)
        rep = getattr(job, "replica", None)
        if rep is not None:
            assert rep.conserved()
        return out


def test_serving_scenarios_registered():
    for name in ("serve_diurnal", "serve_bursty"):
        assert name in SERVING_SCENARIOS
        sc = build_scenario(name, scale=0.1, seed=1)
        assert sc.fragments and sc.requests
        assert all(isinstance(r, RequestSpec) for r in sc.requests)
        assert all(r.profile in REQUEST_PROFILES for r in sc.requests)


def test_run_serving_end_to_end_conserves_requests():
    rep = run_serving("serve_diurnal", scale=0.1, seed=0, audit=True,
                      allocator=AllocationEngine(time_budget=0.0))
    s = rep.summary
    assert rep.requests > 0 and rep.served > 0
    assert s["offered"] == s["arrived"]          # the tail was ingested
    assert s["arrived"] == rep.served + rep.dropped + s["pending"]
    assert 0.0 <= rep.slo_attainment <= 1.0
    assert rep.requests_per_sec > 0.0
    assert rep.latency_ms_p50 <= rep.latency_ms_p95 <= rep.latency_ms_p99
    for job in rep.jobs:
        assert all(n > 0 for _, _, n in job.replica.audit)


def test_serving_loop_invariants_under_checking_backend():
    """Replay serve_bursty through the assertion-heavy backend: every
    advance preserves conservation and never serves on ungranted
    node-seconds (the backend raises otherwise)."""
    sc = build_scenario("serve_bursty", scale=0.1, seed=2)
    from repro.serving import make_serving_jobs
    jobs = make_serving_jobs(sc.requests, sc.duration, seed=2, audit=True)
    loop = ControlLoop(fragments_to_events(sc.fragments), jobs,
                       AllocationEngine(time_budget=0.0), _CheckingBackend(),
                       t_fwd=120.0, horizon=sc.duration,
                       objective="latency_slo")
    stats = loop.run()
    assert all(r.allocated <= r.pool_size for r in stats.event_records)
    assert sum(j.replica.served for j in jobs) > 0


def test_mixed_training_and_serving_pool():
    """Training jobs (rate=None) share the pool with serving jobs under
    the latency_slo policy; both make progress."""
    trainer = TrainerJob(id=0, curve=tab2_curve("ShuffleNet"), work=1e7,
                         n_min=1, n_max=4, r_up=10.0, r_dw=3.0)
    rep = run_serving("serve_diurnal", scale=0.1, seed=1,
                      trainers=[trainer],
                      allocator=AllocationEngine(time_budget=0.0))
    assert trainer.done > 0
    assert rep.served > 0


def test_dedicated_baseline_matches_demand():
    elastic = run_serving("serve_diurnal", scale=0.1, seed=0,
                          allocator=AllocationEngine(time_budget=0.0))
    ded = dedicated_baseline("serve_diurnal", scale=0.1, seed=0)
    assert ded.summary["dedicated_nodes"] >= 1
    assert ded.requests == elastic.requests      # identical traces
    assert 0.0 <= ded.slo_attainment <= 1.0
    # a static, peak-provisioned pool never loses requests to rescaling
    assert ded.summary["dropped_kill"] == 0


# ---------------------------------------------------------------------------
# Zero-serving parity regression (ISSUE 9 satellite b)
# ---------------------------------------------------------------------------


def _training_jobs():
    return [TrainerJob(id=i, curve=tab2_curve(name), work=5e7, n_min=1,
                       n_max=6, r_up=10.0, r_dw=3.0, weight=1.0 + i,
                       deadline=3e4 * (i + 1), budget=4e5)
            for i, name in enumerate(["ResNet18", "ShuffleNet", "AlexNet"])]


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_zero_serving_replays_bit_identical(name):
    """With serving importable but no serving jobs, ServingBackend must
    be invisible: replays match AnalyticBackend bit for bit on every
    scenario under every training policy."""
    sc = build_scenario(name, scale=0.05, seed=2)
    events = fragments_to_events(sc.fragments)

    def run(backend):
        return ControlLoop(events, _training_jobs(),
                           AllocationEngine(time_budget=0.0), backend,
                           t_fwd=120.0, horizon=sc.duration,
                           objective=policy).run()

    for policy in ("throughput", "weighted", "maxmin", "deadline",
                   "costcap"):
        base = run(AnalyticBackend())
        serv = run(ServingBackend())
        assert base.total_samples == serv.total_samples
        assert len(base.event_records) == len(serv.event_records)
        for a, b in zip(base.event_records, serv.event_records):
            assert a.time == b.time
            assert a.pool_size == b.pool_size
            assert a.allocated == b.allocated
            assert a.outcome_until_next == b.outcome_until_next
            assert a.rescale_cost_samples == b.rescale_cost_samples
