"""Minimal, deterministic stand-in for the ``hypothesis`` library.

The three property-test modules (``test_property.py`` and the inner
properties in ``test_federation.py`` / ``test_objectives.py``) only need
a small slice of hypothesis: ``@given``/``@settings`` and a handful of
strategies.  When the real library is installed (CI does install it)
this module is never imported; otherwise ``tests/conftest.py`` calls
:func:`install` so the perpetually-skipped tier-1 properties run
everywhere.

Differences from real hypothesis, deliberately accepted:

* examples are drawn from a PRNG seeded by ``(test qualname, index)`` —
  fully deterministic, no example database, no shrinking;
* ``settings`` honors ``max_examples`` and ignores everything else
  (``deadline`` etc.);
* only the strategies the suite uses are provided.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types

__all__ = ["install", "given", "settings", "STRATEGIES"]

#: examples per property when no ``@settings(max_examples=...)`` is given
DEFAULT_EXAMPLES = 25


# ---------------------------------------------------------------------------
# Strategies
# ---------------------------------------------------------------------------


class Strategy:
    """Base: a strategy draws one value from a ``random.Random``."""

    def example(self, rnd: random.Random):  # pragma: no cover - abstract
        raise NotImplementedError

    # hypothesis-compatible conveniences (unused by the suite but cheap)
    def map(self, fn):
        return _MappedStrategy(self, fn)

    def filter(self, pred):
        return _FilteredStrategy(self, pred)


class _MappedStrategy(Strategy):
    def __init__(self, base, fn):
        self.base, self.fn = base, fn

    def example(self, rnd):
        return self.fn(self.base.example(rnd))


class _FilteredStrategy(Strategy):
    def __init__(self, base, pred):
        self.base, self.pred = base, pred

    def example(self, rnd):
        for _ in range(1000):
            v = self.base.example(rnd)
            if self.pred(v):
                return v
        raise ValueError("filter predicate rejected 1000 examples")


class _Integers(Strategy):
    def __init__(self, min_value=None, max_value=None):
        self.lo = -(2 ** 31) if min_value is None else int(min_value)
        self.hi = 2 ** 31 if max_value is None else int(max_value)

    def example(self, rnd):
        if rnd.random() < 0.1:          # nudge the endpoints occasionally
            return rnd.choice((self.lo, self.hi))
        return rnd.randint(self.lo, self.hi)


class _Floats(Strategy):
    def __init__(self, min_value=None, max_value=None, **_ignored):
        self.lo = 0.0 if min_value is None else float(min_value)
        self.hi = 1.0 if max_value is None else float(max_value)

    def example(self, rnd):
        if rnd.random() < 0.1:
            return rnd.choice((self.lo, self.hi))
        return rnd.uniform(self.lo, self.hi)


class _Booleans(Strategy):
    def example(self, rnd):
        return rnd.random() < 0.5


class _NoneStrategy(Strategy):
    def example(self, rnd):
        return None


class _SampledFrom(Strategy):
    def __init__(self, elements):
        self.elements = list(elements)
        if not self.elements:
            raise ValueError("sampled_from requires a non-empty sequence")

    def example(self, rnd):
        return rnd.choice(self.elements)


class _Lists(Strategy):
    def __init__(self, elements, min_size=0, max_size=None):
        self.elements = elements
        self.min_size = int(min_size)
        self.max_size = self.min_size + 10 if max_size is None \
            else int(max_size)

    def example(self, rnd):
        n = rnd.randint(self.min_size, self.max_size)
        return [self.elements.example(rnd) for _ in range(n)]


class _Tuples(Strategy):
    def __init__(self, *strategies):
        self.strategies = strategies

    def example(self, rnd):
        return tuple(s.example(rnd) for s in self.strategies)


class _OneOf(Strategy):
    def __init__(self, *strategies):
        self.strategies = strategies

    def example(self, rnd):
        return rnd.choice(self.strategies).example(rnd)


class _Composite(Strategy):
    def __init__(self, fn, args, kwargs):
        self.fn, self.args, self.kwargs = fn, args, kwargs

    def example(self, rnd):
        draw = lambda strategy: strategy.example(rnd)  # noqa: E731
        return self.fn(draw, *self.args, **self.kwargs)


def composite(fn):
    """``@st.composite`` — decorate ``fn(draw, ...)``; calling the result
    (e.g. ``milp_instances()``) yields a strategy."""
    @functools.wraps(fn)
    def make(*args, **kwargs):
        return _Composite(fn, args, kwargs)
    return make


STRATEGIES = {
    "integers": _Integers,
    "floats": _Floats,
    "booleans": _Booleans,
    "none": _NoneStrategy,
    "sampled_from": _SampledFrom,
    "lists": _Lists,
    "tuples": _Tuples,
    "one_of": _OneOf,
    "composite": composite,
    "just": lambda v: _SampledFrom([v]),
}


# ---------------------------------------------------------------------------
# @given / @settings
# ---------------------------------------------------------------------------


def settings(max_examples=None, **_ignored):
    """Record ``max_examples`` on the decorated function.  Works in both
    stacking orders: below ``@given`` (attribute copied into the runner
    by ``functools.wraps``) and above it (attribute set on the runner,
    read at call time)."""
    def deco(fn):
        if max_examples is not None:
            fn._stub_max_examples = int(max_examples)
        return fn
    return deco


def given(*strategies, **kw_strategies):
    if kw_strategies:
        raise TypeError("stub @given supports positional strategies only")

    def deco(fn):
        @functools.wraps(fn)
        def runner(*args, **kwargs):
            n = getattr(runner, "_stub_max_examples", DEFAULT_EXAMPLES)
            qual = getattr(fn, "__qualname__", fn.__name__)
            for i in range(n):
                rnd = random.Random(f"{qual}:{i}")
                drawn = tuple(s.example(rnd) for s in strategies)
                try:
                    fn(*args, *drawn, **kwargs)
                except Exception as exc:
                    raise AssertionError(
                        f"property {qual} falsified on example {i}: "
                        f"{drawn!r}") from exc
            return None
        # strategies fill the TRAILING parameters; expose only the rest
        # so pytest does not mistake property arguments for fixtures
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        keep = params[:len(params) - len(strategies)]
        runner.__signature__ = sig.replace(parameters=keep)
        del runner.__wrapped__
        runner.hypothesis = types.SimpleNamespace(inner_test=fn)
        return runner
    return deco


# ---------------------------------------------------------------------------
# Module installation
# ---------------------------------------------------------------------------


def install():
    """Register stub ``hypothesis`` / ``hypothesis.strategies`` modules
    (no-op if the real library is already importable)."""
    if "hypothesis" in sys.modules:
        return sys.modules["hypothesis"]
    hyp = types.ModuleType("hypothesis")
    strat = types.ModuleType("hypothesis.strategies")
    for name, obj in STRATEGIES.items():
        setattr(strat, name, obj)
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = strat
    hyp.__version__ = "0.0.stub"
    hyp.__is_stub__ = True
    hyp.HealthCheck = types.SimpleNamespace(
        too_slow=None, filter_too_much=None, data_too_large=None)
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strat
    return hyp
